"""Stdlib HTTP scrape endpoint for the health plane.

``GatewayConfig(metrics_port=...)`` starts one of these next to the
gateway.  Three routes, all GET:

  ``/metrics``  Prometheus text exposition of ``snapshot_stats()``
  ``/health``   JSON health report; 200 unless the overall status is
                ``critical`` -> 503 (load-balancer friendly)
  ``/slowlog``  the slow-request span trees as JSON

Port 0 binds an ephemeral port (tests); the bound port is exposed as
``server.port``.  Built on ``http.server.ThreadingHTTPServer`` so the
repo stays dependency-free.
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, List, Optional

from .export import prometheus_text

__all__ = ["HealthHTTPServer"]


class HealthHTTPServer:
    """Serve /metrics, /health, and /slowlog for one gateway."""

    def __init__(self, stats_fn: Callable[[], dict],
                 health_fn: Callable[[], dict],
                 slowlog_fn: Optional[Callable[[], List[dict]]] = None,
                 host: str = "127.0.0.1", port: int = 0,
                 namespace: str = "repro"):
        self.stats_fn = stats_fn
        self.health_fn = health_fn
        self.slowlog_fn = slowlog_fn
        self.namespace = namespace
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, fmt, *args):  # noqa: D102 - silence stderr
                pass

            def do_GET(self):  # noqa: N802 - http.server API
                try:
                    path = self.path.split("?", 1)[0]
                    if path == "/metrics":
                        body = prometheus_text(
                            outer.stats_fn(), namespace=outer.namespace)
                        self._send(200, body.encode("utf-8"),
                                   "text/plain; version=0.0.4; charset=utf-8")
                    elif path == "/health":
                        report = outer.health_fn()
                        code = 503 if report.get("status") == "critical" else 200
                        self._send_json(code, report)
                    elif path == "/slowlog":
                        entries = outer.slowlog_fn() if outer.slowlog_fn else []
                        self._send_json(200, {"slow_requests": entries})
                    else:
                        self._send_json(404, {"error": f"no route {path}"})
                except Exception as exc:  # surface handler bugs as 500s
                    try:
                        self._send_json(500, {"error": repr(exc)})
                    except Exception:
                        pass

            def _send_json(self, code: int, payload: dict):
                body = json.dumps(payload, sort_keys=True).encode("utf-8")
                self._send(code, body, "application/json")

            def _send(self, code: int, body: bytes, ctype: str):
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self.host = host
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="obs-http", daemon=True,
            kwargs={"poll_interval": 0.1})
        self._thread.start()
        self._closed = False

    def close(self, timeout: float = 2.0) -> None:
        if self._closed:
            return
        self._closed = True
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=timeout)
