"""Exposition helpers: Prometheus text format + slow-log dumps.

The gateway's ``snapshot_stats()`` is a nested JSON-safe tree (gateway
+ engine ``per_device`` + runtime + WAL + blockstore + obs).
``flatten`` walks it into ``path/to/leaf -> number`` pairs and
``prometheus_text`` renders those as one-metric-per-line text
exposition, so any scraper can consume the same snapshot the
``OP_STATS`` wire verb returns.
"""

from __future__ import annotations

import json
import re
from typing import Dict, List, Mapping

_NAME_BAD = re.compile(r"[^a-zA-Z0-9_:]")


def flatten(tree: Mapping, prefix: str = "") -> Dict[str, float]:
    """Flatten a nested stats tree to {joined/key: numeric leaf}."""
    out: Dict[str, float] = {}
    for key, value in tree.items():
        path = f"{prefix}/{key}" if prefix else str(key)
        if isinstance(value, Mapping):
            out.update(flatten(value, path))
        elif isinstance(value, bool):
            out[path] = 1.0 if value else 0.0
        elif isinstance(value, (int, float)):
            out[path] = float(value)
        elif isinstance(value, (list, tuple)):
            for i, item in enumerate(value):
                if isinstance(item, Mapping):
                    out.update(flatten(item, f"{path}/{i}"))
                elif isinstance(item, (int, float)) and not isinstance(item, bool):
                    out[f"{path}/{i}"] = float(item)
        # strings and other non-numeric leaves are dropped from exposition
    return out


def metric_name(path: str, namespace: str = "repro") -> str:
    name = _NAME_BAD.sub("_", path.replace("/", "_"))
    return f"{namespace}_{name}" if namespace else name


def prometheus_text(tree: Mapping, namespace: str = "repro") -> str:
    """Render a nested stats tree as Prometheus text exposition."""
    lines: List[str] = []
    for path, value in sorted(flatten(tree).items()):
        if value == int(value) and abs(value) < 2**53:
            rendered = str(int(value))
        else:
            rendered = repr(value)
        lines.append(f"{metric_name(path, namespace)} {rendered}")
    return "\n".join(lines) + ("\n" if lines else "")


def dump_slow_log(entries: List[Dict], path: str) -> bool:
    """Write the slow-request span trees to ``path`` (JSON).

    Only writes when there is something to report; returns whether a
    file was written, so CI can upload the artifact conditionally.
    """
    if not entries:
        return False
    with open(path, "w", encoding="utf-8") as fh:
        json.dump({"slow_requests": entries}, fh, indent=1, sort_keys=True)
        fh.write("\n")
    return True
