"""Exposition helpers: Prometheus text format + slow-log dumps.

The gateway's ``snapshot_stats()`` is a nested JSON-safe tree (gateway
+ engine ``per_device`` + runtime + WAL + blockstore + obs).
``flatten`` walks it into ``path/to/leaf -> number`` pairs and
``prometheus_text`` renders those as one-metric-per-line text
exposition, so any scraper can consume the same snapshot the
``OP_STATS`` wire verb returns.
"""

from __future__ import annotations

import json
import math
import re
from typing import Dict, List, Mapping, Tuple

_NAME_BAD = re.compile(r"[^a-zA-Z0-9_:]")

# leaf names that are monotonically increasing in the snapshot tree;
# everything else is exposed as a gauge (point-in-time semantics)
_COUNTER_LEAVES = frozenset({
    "count", "beats", "jobs", "launches", "bytes", "coalesced",
    "appends", "fsyncs", "snapshots", "flush_waits", "frames",
    "dispatched", "submitted", "completed", "rejected", "errors",
    "bytes_in", "bytes_out", "admission_rejections", "puts",
    "skipped_puts", "replaced", "drops", "flushes", "scanned_records",
    "scrubbed_blocks", "corrupt_found", "repairs_enqueued", "evals",
    "samples", "stats_truncated", "manager_restarts", "finished",
})


def _escape_key(key) -> str:
    """Percent-escape the path separator (and '%' itself) in one tree
    key, so a tenant named ``a/b`` can't flatten to the same metric name
    as the genuinely nested path ``a -> b``."""
    k = str(key)
    if "%" in k or "/" in k:
        k = k.replace("%", "%25").replace("/", "%2F")
    return k


def flatten(tree: Mapping, prefix: str = "") -> Dict[str, float]:
    """Flatten a nested stats tree to {joined/key: numeric leaf}.

    ``/`` inside a single key is escaped as ``%2F`` (and ``%`` as
    ``%25``): distinct tree paths always flatten to distinct names, and
    consumers that split on ``/`` (the health rules, prometheus_text)
    recover the exact component boundaries."""
    out: Dict[str, float] = {}
    for key, value in tree.items():
        ekey = _escape_key(key)
        path = f"{prefix}/{ekey}" if prefix else ekey
        if isinstance(value, Mapping):
            out.update(flatten(value, path))
        elif isinstance(value, bool):
            out[path] = 1.0 if value else 0.0
        elif isinstance(value, (int, float)):
            out[path] = float(value)
        elif isinstance(value, (list, tuple)):
            for i, item in enumerate(value):
                if isinstance(item, Mapping):
                    out.update(flatten(item, f"{path}/{i}"))
                elif isinstance(item, (int, float)) and not isinstance(item, bool):
                    out[f"{path}/{i}"] = float(item)
        # strings and other non-numeric leaves are dropped from exposition
    return out


def metric_name(path: str, namespace: str = "repro") -> str:
    name = _NAME_BAD.sub("_", path.replace("/", "_"))
    return f"{namespace}_{name}" if namespace else name


def _render_value(value: float) -> str:
    # Prometheus exposition spells non-finite values +Inf/-Inf/NaN;
    # Python's repr() renders inf/nan (invalid), and int(value) raises
    # on them outright, so the finiteness check must come first.
    if not math.isfinite(value):
        if math.isnan(value):
            return "NaN"
        return "+Inf" if value > 0 else "-Inf"
    if value == int(value) and abs(value) < 2**53:
        return str(int(value))
    return repr(value)


def prometheus_text(tree: Mapping, namespace: str = "repro") -> str:
    """Render a nested stats tree as Prometheus text exposition,
    including ``# TYPE`` metadata (counter for known monotonic leaf
    names, gauge otherwise)."""
    lines: List[str] = []
    for path, value in sorted(flatten(tree).items()):
        name = metric_name(path, namespace)
        leaf = path.rsplit("/", 1)[-1]
        mtype = "counter" if leaf in _COUNTER_LEAVES else "gauge"
        lines.append(f"# TYPE {name} {mtype}")
        lines.append(f"{name} {_render_value(value)}")
    return "\n".join(lines) + ("\n" if lines else "")


def truncate_tree(tree: Mapping, max_bytes: int,
                  reserve: int = 64) -> Tuple[Dict, int]:
    """Deterministically shrink ``tree`` until its sorted-JSON encoding
    fits ``max_bytes``: drop the deepest mapping subtrees first (coarse
    per-device/per-tenant detail goes before headline counters), then
    scalar leaves bottom-up as a last resort.  Returns ``(pruned_copy,
    dropped_subtree_count)`` — the copy carries a root
    ``stats_truncated`` marker when anything was dropped.

    Used to bound ``OP_STATS`` / ``OP_HEALTH`` replies against
    ``max_frame_bytes`` instead of letting an overgrown stats tree kill
    the connection with an oversized frame.
    """
    out = json.loads(json.dumps(tree, sort_keys=True))  # deep JSON-safe copy
    budget = max(256, int(max_bytes) - reserve)
    dropped = 0

    def size() -> int:
        return len(json.dumps(out, sort_keys=True).encode("utf-8"))

    def mapping_depths(node, depth=0):
        yield depth, node
        for key in sorted(node):
            child = node[key]
            if isinstance(child, dict):
                yield from mapping_depths(child, depth + 1)

    while size() > budget:
        deepest = max(d for d, _ in mapping_depths(out))
        if deepest > 0:
            # prune every mapping at the deepest level in one pass
            def prune(node, depth=0):
                nonlocal dropped
                for key in sorted(node):
                    child = node[key]
                    if isinstance(child, dict):
                        if depth + 1 == deepest:
                            node[key] = "<truncated>"
                            dropped += 1
                        else:
                            prune(child, depth + 1)
            prune(out)
        else:
            # only root scalars left: drop keys from the sort tail
            keys = sorted(k for k in out if k != "stats_truncated")
            if not keys:
                break
            del out[keys[-1]]
            dropped += 1
        out["stats_truncated"] = dropped
    if dropped:
        out["stats_truncated"] = dropped
    return out, dropped


def dump_slow_log(entries: List[Dict], path: str) -> bool:
    """Write the slow-request span trees to ``path`` (JSON).

    Only writes when there is something to report; returns whether a
    file was written, so CI can upload the artifact conditionally.
    """
    if not entries:
        return False
    with open(path, "w", encoding="utf-8") as fh:
        json.dump({"slow_requests": entries}, fh, indent=1, sort_keys=True)
        fh.write("\n")
    return True
