"""Health plane: thread heartbeats + rule-driven verdicts.

Two halves:

* :class:`Heartbeat` / :class:`HeartbeatBoard` — instrumentation every
  long-lived thread in the stack carries (engine manager loops, the WAL
  flusher, scrub/maintenance loops, SAI pipeline stages, the gateway
  scheduler).  A thread ``beat()``s at the top of each work iteration
  and ``park()``s before blocking indefinitely (empty queue, paused
  runtime, clean exit), so "no recent beat" is distinguishable from
  "legitimately idle".

* :class:`HealthEngine` — evaluates rule-driven verdicts over the
  rolling samples a :class:`repro.obs.timeseries.MetricsSampler`
  collects from the gateway stats tree:

  ========================  =======================================
  rule                      fires when
  ========================  =======================================
  ``*_stalled``             an unparked heartbeat's age exceeds
                            ``stall_after_s`` (per long-lived thread)
  ``sampler_stalled``       the sampler itself stopped producing
  ``device_straggler``      a device's EWMA slowdown exceeds
                            ``straggler_ratio`` x the mesh median
                            while the mesh is taking launches
  ``backlog_growth``        a lane queue depth grew across the
                            window past ``backlog_min_depth``
  ``slo_burn``              a QoS class's windowed latency-violation
                            rate burns its error budget faster than
                            ``burn_warn`` / ``burn_critical``
  ========================  =======================================

Verdicts are plain JSON-safe dicts so they can ride the ``OP_HEALTH``
wire verb, the ``/health`` HTTP endpoint, and ``snapshot_stats()``
unchanged.
"""

from __future__ import annotations

import re
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Tuple

__all__ = [
    "Heartbeat",
    "HeartbeatBoard",
    "HealthConfig",
    "HealthEngine",
    "STATUS_OK",
    "STATUS_WARN",
    "STATUS_CRITICAL",
]

STATUS_OK = "ok"
STATUS_WARN = "warn"
STATUS_CRITICAL = "critical"

_STATUS_RANK = {STATUS_OK: 0, STATUS_WARN: 1, STATUS_CRITICAL: 2}

_VERDICT_BAD = re.compile(r"[^a-zA-Z0-9_]")


class Heartbeat:
    """Liveness stamp for one long-lived thread.

    ``beat()`` marks forward progress; ``park()`` declares the thread
    intentionally dormant (blocking on an empty queue, paused, or
    exited cleanly) so the watchdog skips it instead of reading the
    growing age as a stall."""

    __slots__ = ("name", "_lock", "_last", "_parked", "_beats")

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._last = time.perf_counter()
        self._parked = True  # not alive until the first beat
        self._beats = 0

    def beat(self) -> None:
        with self._lock:
            self._last = time.perf_counter()
            self._parked = False
            self._beats += 1

    def park(self) -> None:
        with self._lock:
            self._last = time.perf_counter()
            self._parked = True

    def state(self) -> Dict[str, float]:
        with self._lock:
            return {
                "age_s": max(0.0, time.perf_counter() - self._last),
                "parked": 1 if self._parked else 0,
                "beats": self._beats,
            }


class HeartbeatBoard:
    """A component's set of heartbeats, snapshot-able as a stats block."""

    def __init__(self):
        self._lock = threading.Lock()
        self._beats: Dict[str, Heartbeat] = {}

    def heartbeat(self, name: str) -> Heartbeat:
        with self._lock:
            hb = self._beats.get(name)
            if hb is None:
                hb = self._beats[name] = Heartbeat(name)
            return hb

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        with self._lock:
            beats = list(self._beats.items())
        return {name: hb.state() for name, hb in beats}


@dataclass
class HealthConfig:
    """Knobs for every verdict rule (see module docstring table)."""

    # heartbeat watchdog: an unparked heartbeat older than this is a stall
    stall_after_s: float = 2.0
    # straggler: device slowdown must exceed ratio x mesh-median slowdown
    # AND the absolute floor, while the mesh took launches this window
    straggler_ratio: float = 3.0
    straggler_min_slowdown: float = 2.0
    # backlog: lane depth must end the window above min_depth and above
    # growth_factor x its depth at the window start
    backlog_min_depth: int = 32
    backlog_growth_factor: float = 2.0
    # SLO: per-QoS p-latency objective (seconds) + allowed violation
    # fraction; burn = (violation_rate / slo_budget)
    slo_p99_s: Dict[str, float] = field(
        default_factory=lambda: {"interactive": 0.5, "batch": 2.0, "scrub": 10.0}
    )
    slo_budget: float = 0.01
    burn_warn: float = 1.0
    burn_critical: float = 10.0
    # minimum windowed request count before the SLO rule has signal
    slo_min_count: int = 8


def _verdict_name(*parts: str) -> str:
    return _VERDICT_BAD.sub("_", "_".join(p for p in parts if p))


class HealthEngine:
    """Evaluates rule verdicts over a MetricsSampler's rolling window."""

    def __init__(self, sampler, config: Optional[HealthConfig] = None):
        self.sampler = sampler
        self.cfg = config or HealthConfig()
        self._lock = threading.Lock()
        self._last_report: Optional[Dict] = None
        self._evals = 0

    # -- rules -------------------------------------------------------

    def _rule_heartbeats(self, flat: Mapping[str, float], out: List[Dict]):
        cfg = self.cfg
        for path, age in flat.items():
            if not path.endswith("/age_s"):
                continue
            if ("/heartbeats/" not in path
                    and not path.startswith("heartbeats/")):
                continue
            base = path[: -len("/age_s")]
            if flat.get(base + "/parked", 0.0):
                continue
            if age <= cfg.stall_after_s:
                continue
            parts = base.split("/")
            idx = parts.index("heartbeats")
            prefix = parts[idx - 1] if idx > 0 else "gateway"
            name = _verdict_name(prefix, "_".join(parts[idx + 1:]), "stalled")
            out.append({
                "rule": "heartbeat",
                "name": name,
                "status": STATUS_CRITICAL,
                "value": round(age, 6),
                "detail": f"thread {base} last beat {age:.3f}s ago "
                          f"(stall_after_s={cfg.stall_after_s})",
            })

    def _rule_sampler(self, out: List[Dict]):
        s = self.sampler
        if not s.running or not s.samples:
            return
        age = time.perf_counter() - s.samples[-1][0]
        limit = max(self.cfg.stall_after_s, 4.0 * s.interval_s)
        if age > limit:
            out.append({
                "rule": "heartbeat",
                "name": "metrics_sampler_stalled",
                "status": STATUS_CRITICAL,
                "value": round(age, 6),
                "detail": f"sampler last tick {age:.3f}s ago "
                          f"(interval_s={s.interval_s})",
            })

    def _rule_straggler(self, flat: Mapping[str, float], out: List[Dict]):
        cfg = self.cfg
        devices: Dict[int, float] = {}
        for path, value in flat.items():
            m = re.fullmatch(r"engine/per_device/(\d+)/slowdown", path)
            if m:
                devices[int(m.group(1))] = value
        if len(devices) < 2:
            return
        # only judge devices that took launches this window: an idle
        # peer's default slowdown of 1.0 is not a comparison point, and
        # a stale slowdown on a drained mesh is history, not a live
        # straggler.  Needs >= 2 active peers — "slow relative to whom?"
        active = {
            i: slow for i, slow in devices.items()
            if (self.sampler.delta(f"engine/per_device/{i}/launches")
                or 0.0) >= 1.0
        }
        if len(active) < 2:
            return
        ranked = sorted(active.values())
        median = ranked[len(ranked) // 2]
        floor = max(cfg.straggler_min_slowdown, cfg.straggler_ratio * median)
        for i, slow in sorted(active.items()):
            if slow >= floor:
                out.append({
                    "rule": "straggler",
                    "name": "device_straggler",
                    "status": STATUS_CRITICAL,
                    "device": i,
                    "value": round(slow, 4),
                    "detail": f"device {i} slowdown {slow:.2f} vs mesh "
                              f"median {median:.2f} "
                              f"(ratio={cfg.straggler_ratio})",
                })

    def _rule_backlog(self, flat: Mapping[str, float], out: List[Dict]):
        cfg = self.cfg
        for path, depth in flat.items():
            if not re.fullmatch(r"(?:engine/)?queue_depths/\w+", path):
                continue
            if depth < cfg.backlog_min_depth:
                continue
            series = self.sampler.series(path)
            if len(series) < 2:
                continue
            start = series[0][1]
            if depth > max(start * cfg.backlog_growth_factor,
                           start + cfg.backlog_min_depth - 1):
                lane = path.rsplit("/", 1)[1]
                out.append({
                    "rule": "backlog",
                    "name": "backlog_growth",
                    "status": STATUS_WARN,
                    "lane": lane,
                    "value": depth,
                    "detail": f"lane {lane} depth {int(start)} -> "
                              f"{int(depth)} over sampler window",
                })

    def _rule_slo(self, flat: Mapping[str, float], out: List[Dict]):
        cfg = self.cfg
        for qos, slo_s in sorted(cfg.slo_p99_s.items()):
            prefix = f"obs/qos/{qos}/buckets/"
            bucket_keys = [k for k in flat if k.startswith(prefix)]
            if not bucket_keys:
                continue
            threshold_ns = max(1, int(slo_s * 1e9))
            # histogram bucket i holds samples whose latency-ns has
            # bit_length == i, i.e. [2^(i-1), 2^i); the first bucket
            # lying entirely at/above the SLO threshold:
            idx_start = (threshold_ns - 1).bit_length() + 1
            total = 0.0
            violations = 0.0
            for key in bucket_keys:
                delta = self.sampler.delta(key)
                if not delta or delta <= 0:
                    continue
                total += delta
                if int(key.rsplit("/", 1)[1]) >= idx_start:
                    violations += delta
            if total < cfg.slo_min_count:
                continue
            burn = (violations / total) / max(cfg.slo_budget, 1e-9)
            if burn < cfg.burn_warn:
                continue
            status = (STATUS_CRITICAL if burn >= cfg.burn_critical
                      else STATUS_WARN)
            out.append({
                "rule": "slo",
                "name": _verdict_name("slo_burn", qos),
                "status": status,
                "qos": qos,
                "value": round(burn, 4),
                "detail": f"{qos}: {int(violations)}/{int(total)} windowed "
                          f"requests over {slo_s}s SLO; burn {burn:.1f}x "
                          f"budget {cfg.slo_budget}",
            })

    # -- evaluation --------------------------------------------------

    def evaluate(self) -> Dict:
        """Run every rule against the sampler's latest window."""
        flat = self.sampler.latest_flat()
        verdicts: List[Dict] = []
        if flat is not None:
            self._rule_heartbeats(flat, verdicts)
            self._rule_sampler(verdicts)
            self._rule_straggler(flat, verdicts)
            self._rule_backlog(flat, verdicts)
            self._rule_slo(flat, verdicts)
        status = STATUS_OK
        for v in verdicts:
            if _STATUS_RANK[v["status"]] > _STATUS_RANK[status]:
                status = v["status"]
        verdicts.sort(key=lambda v: (-_STATUS_RANK[v["status"]], v["name"]))
        with self._lock:
            self._evals += 1
            report = {
                "status": status,
                "healthy": status != STATUS_CRITICAL,
                "verdicts": verdicts,
                "samples": len(self.sampler.samples),
                "evals": self._evals,
            }
            self._last_report = report
        return report

    def snapshot(self) -> Dict:
        """Most recent report (evaluating once if none exists yet)."""
        with self._lock:
            report = self._last_report
        return report if report is not None else self.evaluate()
