"""Rolling time-series over the gateway stats tree.

:class:`MetricsSampler` periodically snapshots a stats callback
(normally ``StorageGateway`` internals), flattens each tree with
:func:`repro.obs.export.flatten`, and keeps ``(t, flat)`` pairs in a
bounded ring.  Diffing consecutive samples turns the stack's cumulative
counters into windowed rates — writes/s, hashed bytes/s, per-device
launches/s, WDRR queue-wait trend — without any layer having to
maintain its own rate state.

The sampler is also the data plane for
:class:`repro.obs.health.HealthEngine`: heartbeat ages, device
slowdowns, lane depths, and QoS histogram buckets are all read from
the same ring.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Mapping, Optional, Tuple

from .export import flatten

__all__ = ["MetricsSampler"]


class MetricsSampler:
    """Background sampler: bounded ring of flattened stats snapshots.

    ``snapshot_fn`` must return a JSON-safe nested stats tree.  The
    ring holds at most ``capacity`` samples; ``window_s`` bounds how
    far back ``delta``/``rate``/``series`` reach.  ``start()`` spawns
    the daemon thread; ``sample_once()`` works without it (used by the
    on-demand ``OP_HEALTH`` path when the background plane is off)."""

    def __init__(self, snapshot_fn: Callable[[], Mapping],
                 interval_s: float = 0.25, capacity: int = 240,
                 window_s: float = 5.0,
                 listeners: Optional[List[Callable]] = None):
        self.snapshot_fn = snapshot_fn
        self.interval_s = max(0.01, float(interval_s))
        self.capacity = max(2, int(capacity))
        self.window_s = max(self.interval_s, float(window_s))
        self.samples: List[Tuple[float, Dict[str, float]]] = []
        self._listeners: List[Callable] = list(listeners or [])
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.errors = 0

    # -- lifecycle ---------------------------------------------------

    @property
    def running(self) -> bool:
        t = self._thread
        return t is not None and t.is_alive()

    def start(self) -> "MetricsSampler":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._loop, name="obs-sampler", daemon=True)
            self._thread.start()
        return self

    def stop(self, timeout: float = 2.0) -> None:
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout=timeout)

    def add_listener(self, fn: Callable) -> None:
        with self._lock:
            self._listeners.append(fn)

    def _loop(self) -> None:  # ra: disable=RA05(the sampler is the health plane's clock; the metrics_sampler_stalled SLO rule is its watchdog)
        while not self._stop.is_set():
            self.sample_once()
            self._stop.wait(self.interval_s)

    # -- sampling ----------------------------------------------------

    def sample_once(self) -> Optional[Dict[str, float]]:
        try:
            flat = flatten(self.snapshot_fn())
        except Exception:
            self.errors += 1
            return None
        now = time.perf_counter()
        with self._lock:
            self.samples.append((now, flat))
            if len(self.samples) > self.capacity:
                del self.samples[: len(self.samples) - self.capacity]
            listeners = list(self._listeners)
        for fn in listeners:
            try:
                fn()
            except Exception:
                self.errors += 1
        return flat

    # -- window reads ------------------------------------------------

    def latest_flat(self) -> Optional[Dict[str, float]]:
        with self._lock:
            return self.samples[-1][1] if self.samples else None

    def _window_locked(self) -> List[Tuple[float, Dict[str, float]]]:
        if not self.samples:
            return []
        horizon = self.samples[-1][0] - self.window_s
        i = 0
        while i < len(self.samples) - 1 and self.samples[i][0] < horizon:
            i += 1
        return self.samples[i:]

    def delta(self, key: str) -> Optional[float]:
        """latest[key] - window-start[key]; None without two samples."""
        with self._lock:
            win = self._window_locked()
        if len(win) < 2:
            return None
        t0, first = win[0]
        t1, last = win[-1]
        if key not in first or key not in last:
            return None
        return last[key] - first[key]

    def rate(self, key: str) -> Optional[float]:
        """Windowed per-second rate of a cumulative counter key."""
        with self._lock:
            win = self._window_locked()
        if len(win) < 2:
            return None
        t0, first = win[0]
        t1, last = win[-1]
        if key not in first or key not in last or t1 <= t0:
            return None
        return (last[key] - first[key]) / (t1 - t0)

    def series(self, key: str) -> List[Tuple[float, float]]:
        """In-window (t, value) points for one flattened key."""
        with self._lock:
            win = self._window_locked()
        return [(t, flat[key]) for t, flat in win if key in flat]

    def tail(self, n: int = 32,
             prefixes: Optional[List[str]] = None) -> List[Dict]:
        """Last ``n`` ring entries (optionally key-filtered) — the
        artifact shape ``obs-health.json`` carries out of CI."""
        with self._lock:
            win = self.samples[-max(1, n):]
        out = []
        for t, flat in win:
            if prefixes is None:
                kept = dict(flat)
            else:
                kept = {k: v for k, v in flat.items()
                        if any(k.startswith(p) for p in prefixes)}
            out.append({"t": t, "metrics": kept})
        return out

    # -- derived headline block --------------------------------------

    def snapshot(self) -> Dict:
        """The ``timeseries`` block for ``snapshot_stats()``."""
        with self._lock:
            n = len(self.samples)
            span = (self.samples[-1][0] - self.samples[0][0]) if n > 1 else 0.0
        out: Dict = {
            "samples": n,
            "window_s": round(min(span, self.window_s), 6),
            "interval_s": self.interval_s,
            "errors": self.errors,
        }

        def put(name: str, value: Optional[float]):
            if value is not None:
                out[name] = round(value, 6)

        put("writes_per_s", self.rate("obs/request/write/count"))
        put("reads_per_s", self.rate("obs/request/read/count"))
        put("hashed_bytes_per_s", self.rate("engine/bytes"))
        put("launches_per_s", self.rate("engine/launches"))
        flat = self.latest_flat() or {}
        per_device: Dict[str, Dict] = {}
        for key in flat:
            m = key.startswith("engine/per_device/") and key.endswith("/launches")
            if m:
                dev = key.split("/")[2]
                r = self.rate(key)
                if r is not None:
                    per_device.setdefault(dev, {})["launches_per_s"] = round(r, 6)
        if per_device:
            out["per_device"] = per_device
        # WDRR queue-wait trend: windowed mean wait vs lifetime mean
        dc = self.delta("obs/request/queue_wait/count")
        ds = self.delta("obs/request/queue_wait/sum_s")
        if dc and dc > 0 and ds is not None:
            out["queue_wait_mean_s"] = round(ds / dc, 9)
        return out
