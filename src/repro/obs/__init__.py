"""Unified observability plane: metrics, traces, exporters, health.

``obs`` is dependency-free (stdlib only) so every layer — engine, SAI,
WAL, block store, node runtime, gateway, transport — can import it
without cycles.  See docs/OBSERVABILITY.md for the metric-name table,
trace span hierarchy, and health verdict rules.
"""

from .metrics import Counter, CounterGroup, Gauge, Histogram, MetricsRegistry
from .trace import Span, Trace, Tracer
from .export import dump_slow_log, flatten, prometheus_text, truncate_tree
from .health import (
    Heartbeat,
    HeartbeatBoard,
    HealthConfig,
    HealthEngine,
    STATUS_CRITICAL,
    STATUS_OK,
    STATUS_WARN,
)
from .timeseries import MetricsSampler
from .httpexport import HealthHTTPServer

__all__ = [
    "Counter",
    "CounterGroup",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Span",
    "Trace",
    "Tracer",
    "dump_slow_log",
    "flatten",
    "prometheus_text",
    "truncate_tree",
    "Heartbeat",
    "HeartbeatBoard",
    "HealthConfig",
    "HealthEngine",
    "HealthHTTPServer",
    "MetricsSampler",
    "STATUS_CRITICAL",
    "STATUS_OK",
    "STATUS_WARN",
]
