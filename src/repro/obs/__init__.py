"""Unified observability plane: metrics, traces, exporters.

``obs`` is dependency-free (stdlib only) so every layer — engine, SAI,
WAL, block store, node runtime, gateway, transport — can import it
without cycles.  See docs/OBSERVABILITY.md for the metric-name table
and trace span hierarchy.
"""

from .metrics import Counter, CounterGroup, Gauge, Histogram, MetricsRegistry
from .trace import Span, Trace, Tracer
from .export import dump_slow_log, flatten, prometheus_text

__all__ = [
    "Counter",
    "CounterGroup",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Span",
    "Trace",
    "Tracer",
    "dump_slow_log",
    "flatten",
    "prometheus_text",
]
