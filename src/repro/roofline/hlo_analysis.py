"""Mini HLO cost analyzer with while-loop scaling.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
empirically: a 10-step scanned matmul reports the FLOPs of a single
matmul).  Since the model stack scans over layers, roofline terms must
rescale loop bodies by their trip counts.  This module parses
``compiled.as_text()`` (post-SPMD, per-device shapes) into computations,
propagates execution multipliers through ``while`` ops (using the
``known_trip_count`` backend_config XLA attaches, falling back to the
condition-computation constant) and ``fusion calls=``, and accumulates:

  * dot FLOPs      2 * prod(result dims) * prod(lhs contracting dims)
  * HBM bytes      sum over top-level ops of operand + result bytes
                   (the same convention as HloCostAnalysis bytes-accessed)
  * collective wire bytes by type (ring-algorithm conventions)

Everything is returned per-device (post-partitioning shapes).
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%(?P<name>[\w.\-]+)\s*=\s*(?P<rest>.+)$")
_OPNAME_RE = re.compile(
    r"^(?P<result>(?:\([^)]*\)|[\w\[\]{},\s]*?))\s*"
    r"(?P<op>[\w\-]+)\((?P<operands>.*?)\)(?P<attrs>.*)$")
_COMP_START_RE = re.compile(
    r"^(ENTRY\s+)?%?(?P<name>[\w.\-]+)\s+\(.*\)\s*->.*\{")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_COND_BODY_RE = re.compile(
    r"condition=%?(?P<cond>[\w.\-]+)|body=%?(?P<body>[\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?(?P<name>[\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?(?P<name>[\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "iota",
}
_COLLECTIVE_OPS = {"all-reduce", "all-gather", "reduce-scatter",
                   "all-to-all", "collective-permute"}


def _shape_dims(text: str) -> List[Tuple[int, List[int]]]:
    """All (dtype_bytes, dims) found in a shape string (handles tuples)."""
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        dl = [int(d) for d in dims.split(",")] if dims else []
        out.append((_DTYPE_BYTES[dt], dl))
    return out


def _shape_bytes(text: str) -> int:
    total = 0
    for b, dims in _shape_dims(text):
        n = 1
        for d in dims:
            n *= d
        total += b * n
    return total


class Instr:
    __slots__ = ("name", "result", "op", "operands", "attrs", "line")

    def __init__(self, name, result, op, operands, attrs, line):
        self.name = name
        self.result = result
        self.op = op
        self.operands = operands
        self.attrs = attrs
        self.line = line


def _parse(hlo: str):
    comps: Dict[str, List[Instr]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for line in hlo.splitlines():
        m = _COMP_START_RE.match(line)
        if m:
            cur = m.group("name")
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        rest = mi.group("rest")
        mo = _OPNAME_RE.match(rest)
        if not mo:
            continue
        comps[cur].append(Instr(mi.group("name"), mo.group("result").strip(),
                                mo.group("op"), mo.group("operands"),
                                mo.group("attrs"), line))
    return comps, entry


def _build_shape_maps(comps):
    """name -> result shape text, per computation (fallback to global)."""
    local = {c: {i.name: i.result for i in instrs}
             for c, instrs in comps.items()}
    glob: Dict[str, str] = {}
    for c in comps.values():
        for i in c:
            glob.setdefault(i.name, i.result)
    return local, glob


def _multipliers(comps, entry) -> Dict[str, float]:
    mult: Dict[str, float] = defaultdict(float)
    if entry is None:
        return {name: 1.0 for name in comps}
    mult[entry] = 1.0
    order = [entry]
    seen = set()
    while order:
        name = order.pop()
        if name in seen:
            continue
        seen.add(name)
        for i in comps.get(name, []):
            if i.op == "while":
                cb = dict(condition=None, body=None)
                for m in _COND_BODY_RE.finditer(i.line):
                    if m.group("cond"):
                        cb["condition"] = m.group("cond")
                    if m.group("body"):
                        cb["body"] = m.group("body")
                trips = 1
                mt = _TRIP_RE.search(i.line)
                if mt:
                    trips = int(mt.group(1))
                elif cb["condition"] in comps:
                    consts = [int(c) for inst in comps[cb["condition"]]
                              for c in _CONST_RE.findall(inst.line)]
                    consts = [c for c in consts if 1 < c <= 10_000_000]
                    trips = max(consts) if consts else 1
                if cb["body"]:
                    mult[cb["body"]] += mult[name] * trips
                    order.append(cb["body"])
                if cb["condition"]:
                    mult[cb["condition"]] += mult[name] * trips
            elif i.op == "fusion":
                mc = _CALLS_RE.search(i.line)
                if mc:
                    mult[mc.group("name")] += mult[name]
                    order.append(mc.group("name"))
            elif i.op == "call":
                # XLA:CPU wraps parallelized fusions in call ops
                # (e.g. %call = ... call(...), to_apply=%parallel_...);
                # heavy ops inside must inherit the caller's multiplier
                ma = _TO_APPLY_RE.search(i.line)
                if ma and ma.group("name") in comps:
                    mult[ma.group("name")] += mult[name]
                    order.append(ma.group("name"))
    return dict(mult)


def _dot_flops(i: Instr, shape_map, glob) -> float:
    res_dims = _shape_dims(i.result)
    n_res = 1
    for _, dims in res_dims:
        for d in dims:
            n_res *= d
    mlc = _LHS_C_RE.search(i.attrs)
    contract = [int(x) for x in mlc.group(1).split(",")] if mlc and \
        mlc.group(1) else []
    names = _OPERAND_NAME_RE.findall(i.operands)
    k = 1
    if names:
        lhs_shape = shape_map.get(names[0]) or glob.get(names[0], "")
        dims_list = _shape_dims(lhs_shape)
        if dims_list:
            _, ldims = dims_list[0]
            for c in contract:
                if c < len(ldims):
                    k *= ldims[c]
    return 2.0 * n_res * k


def _collective_wire(i: Instr) -> Tuple[str, float]:
    res_b = _shape_bytes(i.result)
    # XLA:CPU's BFloat16Normalization promotes bf16 collectives to f32
    # (no native bf16 reductions on the CPU backend); the TPU pipeline
    # keeps them bf16.  Detect the rewritten '..._promoted' reducer and
    # count wire bytes at the true (bf16) width.
    if "promoted" in i.line and "f32[" in i.result:
        res_b //= 2
    gm = _GROUPS_IOTA_RE.search(i.line)
    if gm:
        n = int(gm.group(2))
    else:
        gl = _GROUPS_LIST_RE.search(i.line)
        n = len(gl.group(1).split(",")) if gl else 2
    n = max(n, 2)
    frac = (n - 1) / n
    op = i.op
    if op == "all-gather":
        return op, frac * res_b
    if op == "all-reduce":
        return op, 2.0 * frac * res_b
    if op == "reduce-scatter":
        return op, frac * n * res_b
    if op == "all-to-all":
        return op, frac * res_b
    return op, float(res_b)          # collective-permute


# ops that are genuine HBM data movement even under perfect fusion
_HEAVY_OPS = {"dot", "gather", "scatter", "dynamic-slice",
              "dynamic-update-slice", "copy", "convolution", "sort",
              "custom-call"}

# elementwise arithmetic (VPU work) — counted per result element, inside
# fusion bodies too; the metric for integer-bound (hashing) kernels where
# XLA's 'flops' undercounts
_VPU_OPS = {"add", "subtract", "multiply", "divide", "and", "or", "xor",
            "not", "shift-left", "shift-right-logical",
            "shift-right-arithmetic", "select", "compare", "maximum",
            "minimum", "tanh", "exponential", "negate", "convert"}


def _result_elems(result: str) -> int:
    n = 0
    for _, dims in _shape_dims(result):
        e = 1
        for d in dims:
            e *= d
        n += e
    return n


def _heavy_bytes(i: "Instr", smap, glob) -> float:
    """HBM traffic estimate for one heavy op.

    Slicing ops read only the slice from HBM, not their (possibly huge,
    e.g. scan-stacked-weights) operand, so they are charged by result /
    update size; dots and copies are charged operands + result.
    """
    res_b = _shape_bytes(i.result)
    if i.op in ("dynamic-slice", "gather"):
        return 2.0 * res_b                       # read slice + write out
    if i.op in ("dynamic-update-slice", "scatter"):
        opnds = []
        for nm in _OPERAND_NAME_RE.findall(i.operands):
            s = smap.get(nm) or glob.get(nm)
            if s:
                opnds.append(_shape_bytes(s))
        upd = min(opnds) if opnds else res_b
        return 2.0 * upd                         # read + write the region
    opd_b = 0
    for nm in _OPERAND_NAME_RE.findall(i.operands):
        s = smap.get(nm) or glob.get(nm)
        if s:
            opd_b += _shape_bytes(s)
    return float(res_b + opd_b)


def analyze_hlo(hlo: str, top_k: int = 12) -> dict:
    comps, entry = _parse(hlo)
    local_maps, glob = _build_shape_maps(comps)
    mult = _multipliers(comps, entry)

    flops = 0.0
    int_ops = 0.0           # elementwise/VPU op count (see _VPU_OPS)
    bytes_upper = 0.0       # no-fusion upper bound (every top-level op r+w)
    bytes_min = 0.0         # perfect-fusion floor (heavy-op traffic only)
    wire: Dict[str, float] = defaultdict(float)
    op_counts: Dict[str, float] = defaultdict(float)
    top_coll: List[tuple] = []
    top_bytes: List[tuple] = []

    fusion_names = set()
    for c, instrs in comps.items():
        for i in instrs:
            if i.op == "fusion":
                mc = _CALLS_RE.search(i.line)
                if mc:
                    fusion_names.add(mc.group("name"))

    for cname, instrs in comps.items():
        m = mult.get(cname, 1.0)
        if m <= 0:
            continue
        smap = local_maps[cname]
        in_fusion = cname in fusion_names
        for i in instrs:
            base_op = i.op.replace("-start", "").replace("-done", "")
            if base_op == "dot":
                flops += m * _dot_flops(i, smap, glob)
            if base_op in _VPU_OPS:
                int_ops += m * _result_elems(i.result)
            if base_op in _COLLECTIVE_OPS and not in_fusion:
                op, w = _collective_wire(i)
                wire[op] += m * w
                op_counts[op] += m
                top_coll.append((m * w, op, i.result[:48], int(m), cname))
            # heavy-op traffic is counted WHERE THE OP LIVES — inside
            # fusion bodies the dynamic-slice result is layer-sized, while
            # the fusion call-site operand would be the full scan-stacked
            # array (32x overcount).  Elementwise-only fusions contribute
            # nothing (perfect-fusion floor).
            if base_op in _HEAVY_OPS and base_op not in _SKIP_BYTES_OPS:
                hb = m * _heavy_bytes(i, smap, glob)
                bytes_min += hb
                top_bytes.append((hb, base_op, i.result[:48], int(m),
                                  cname))
            if in_fusion:
                continue
            if base_op in _SKIP_BYTES_OPS or base_op in _COLLECTIVE_OPS:
                continue
            res_b = _shape_bytes(i.result)
            opd_b = 0
            for nm in _OPERAND_NAME_RE.findall(i.operands):
                s = smap.get(nm) or glob.get(nm)
                if s:
                    opd_b += _shape_bytes(s)
            bytes_upper += m * (res_b + opd_b)

    top_coll.sort(key=lambda t: -t[0])
    top_bytes.sort(key=lambda t: -t[0])
    return {
        "flops": flops,
        "int_ops": int_ops,
        "bytes_accessed": bytes_min,
        "bytes_upper": bytes_upper,
        "wire_bytes": dict(wire),
        "op_counts": {k: int(v) for k, v in op_counts.items()},
        "total_wire_bytes": float(sum(wire.values())),
        "n_computations": len(comps),
        "top_collectives": [
            dict(wire_bytes=w, op=o, result=r, mult=mm, comp=c)
            for w, o, r, mm, c in top_coll[:top_k]],
        "top_bytes": [
            dict(bytes=w, op=o, result=r, mult=mm, comp=c)
            for w, o, r, mm, c in top_bytes[:top_k]],
    }


def collective_bytes_from_hlo(hlo: str) -> dict:
    a = analyze_hlo(hlo)
    return {"wire_bytes": a["wire_bytes"],
            "op_counts": a["op_counts"],
            "total_wire_bytes": a["total_wire_bytes"]}
