from repro.roofline.hlo_analysis import analyze_hlo, collective_bytes_from_hlo  # noqa: F401
from repro.roofline.analysis import roofline_terms, HW  # noqa: F401
