"""Roofline term derivation from dry-run artifacts.

Hardware model: TPU v5e —
  peak bf16 compute  197 TFLOP/s per chip
  HBM bandwidth      819 GB/s per chip
  ICI link bandwidth ~50 GB/s per link

Terms (seconds per step):
  compute    = HLO_FLOPs / (chips * peak)         [FLOPs from cost_analysis;
               cost_analysis counts while bodies ONCE, so scanned-layer
               FLOPs are rescaled by the measured scan calibration factor]
  memory     = HLO_bytes / (chips * HBM_bw)
  collective = per-device wire bytes / link_bw    [parsed from HLO, loop
               bodies scaled by trip count]

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per processed token
count — the 'useful' fraction MODEL_FLOPS / HLO_FLOPs flags remat /
dispatch / padding waste.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Optional

from repro.configs import get_config, get_shape


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12          # bf16 per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    link_bw: float = 50e9               # bytes/s per link


# uint32 ALU ops per byte of hashed input, measured from compiled kernel
# HLO by benchmarks/kernel_roofline.py (same values as
# benchmarks.common.OPS_PER_BYTE, keyed by engine job kind)
HASH_OPS_PER_BYTE = {"direct": 60.9, "sliding": 635.3, "gear": 85.0}

# effective integer-op rate of the interpret-mode (XLA:CPU) host this
# repo measures on — the seed only has to be order-of-magnitude right,
# the engine's KernelCostModel regresses the true rate online
HOST_INT_OPS = 2e9

# per-launch fixed cost seed (dispatch + staging + jit cache hit) on the
# interpret-mode host; also refined online
HOST_LAUNCH_OVERHEAD_S = 2e-3


def hash_cost_seed(kind: str, int_ops_per_s: float = HOST_INT_OPS,
                   launch_overhead_s: float = HOST_LAUNCH_OVERHEAD_S
                   ) -> Dict[str, float]:
    """Seed parameters for the offload engine's launch-cost model:
    ``sec_per_byte`` from the kernel's measured op count over the host
    int-op rate, plus a fixed ``launch_overhead_s``.  The engine
    (repro.core.crystal.KernelCostModel) starts every dispatch decision
    from these and replaces them with EWMA-regressed measured values as
    launches retire."""
    ops_per_byte = HASH_OPS_PER_BYTE.get(kind, 100.0)
    return {"sec_per_byte": ops_per_byte / float(int_ops_per_s),
            "launch_overhead_s": float(launch_overhead_s)}


def model_flops(arch: str, shape_name: str) -> float:
    """6*N*D convention (N = active params, D = tokens processed)."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens          # forward only
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def roofline_terms(rec: dict, hw: HW = HW()) -> dict:
    """rec: one dry-run JSON record -> roofline terms in seconds.

    ``flops_scaled`` / ``bytes_scaled`` / wire bytes come from the HLO
    analyzer and are PER-DEVICE (post-SPMD shapes), with while-loop bodies
    scaled by trip count; terms therefore divide by per-chip rates only.
    """
    chips = rec["n_devices"]
    flops = rec["cost"].get("flops", 0.0)
    flops_scaled = rec.get("flops_scaled") or flops
    hbm_bytes = rec["cost"].get("bytes accessed", 0.0)
    hbm_scaled = rec.get("bytes_scaled") or hbm_bytes
    wire = rec["collectives"]["total_wire_bytes"]

    compute_s = flops_scaled / hw.peak_flops
    memory_s = hbm_scaled / hw.hbm_bw
    collective_s = wire / hw.link_bw

    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound = dominant.replace("_s", "")
    step_s = max(terms.values())

    mflops = model_flops(rec["arch"], rec["shape"]) / chips  # per device
    useful = mflops / flops_scaled if flops_scaled else 0.0
    # roofline fraction: useful model FLOPs over what a chip could do in
    # the bottleneck-imposed step time.
    frac = mflops / (hw.peak_flops * step_s) if step_s else 0.0
    return dict(terms, dominant=bound, step_s=step_s,
                model_flops_per_chip=mflops, hlo_flops=flops_scaled,
                useful_flops_ratio=useful, roofline_fraction=frac)


def load_records(results_dir: str, tag: str = "") -> Dict[str, dict]:
    out = {}
    if not os.path.isdir(results_dir):
        return out
    for fn in sorted(os.listdir(results_dir)):
        if not fn.endswith(".json"):
            continue
        stem = fn[:-5]
        parts = stem.split("__")
        has_tag = len(parts) == 4
        if tag and (not has_tag or parts[3] != tag):
            continue
        if not tag and has_tag:
            continue
        with open(os.path.join(results_dir, fn)) as f:
            out[stem] = json.load(f)
    return out


def format_table(records: Dict[str, dict], hw: HW = HW(),
                 mesh: Optional[str] = "single") -> str:
    rows = []
    header = (f"{'arch':24s} {'shape':12s} {'mesh':6s} "
              f"{'compute_s':>10s} {'memory_s':>10s} {'collect_s':>10s} "
              f"{'bound':>10s} {'useful':>7s} {'roofl%':>7s}")
    rows.append(header)
    rows.append("-" * len(header))
    for key, rec in sorted(records.items()):
        if mesh and rec["mesh"] != mesh:
            continue
        t = roofline_terms(rec, hw)
        rows.append(
            f"{rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:6s} "
            f"{t['compute_s']:10.4f} {t['memory_s']:10.4f} "
            f"{t['collective_s']:10.4f} {t['dominant']:>10s} "
            f"{t['useful_flops_ratio']:7.3f} "
            f"{100*t['roofline_fraction']:6.1f}%")
    return "\n".join(rows)
