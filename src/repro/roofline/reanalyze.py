"""Re-derive roofline fields of dry-run JSONs from stored HLO (no
recompilation).

  PYTHONPATH=src python -m repro.roofline.reanalyze [results_dir]
"""
from __future__ import annotations

import gzip
import json
import os
import sys

from repro.roofline.hlo_analysis import analyze_hlo


def main():
    base = sys.argv[1] if len(sys.argv) > 1 else "results"
    dr = os.path.join(base, "dryrun")
    hlo_dir = os.path.join(base, "hlo")
    n = 0
    for fn in sorted(os.listdir(dr)):
        if not fn.endswith(".json"):
            continue
        stem = fn[:-5]
        hlo_path = os.path.join(hlo_dir, stem + ".hlo.gz")
        if not os.path.exists(hlo_path):
            print(f"[skip] no HLO for {stem}")
            continue
        with gzip.open(hlo_path, "rt") as f:
            hlo = f.read()
        an = analyze_hlo(hlo)
        path = os.path.join(dr, fn)
        with open(path) as f:
            rec = json.load(f)
        rec["flops_scaled"] = an["flops"]
        rec["bytes_scaled"] = an["bytes_accessed"]
        rec["bytes_upper"] = an["bytes_upper"]
        rec["collectives"] = {"wire_bytes": an["wire_bytes"],
                              "op_counts": an["op_counts"],
                              "total_wire_bytes": an["total_wire_bytes"]}
        rec["top_collectives"] = an["top_collectives"]
        rec["top_bytes"] = an["top_bytes"]
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        n += 1
    print(f"reanalyzed {n} records")


if __name__ == "__main__":
    main()
