PYTHON ?= python

.PHONY: test test-fast test-crash dev-deps bench bench-smoke bench-mesh-smoke bench-compare lint-invariants lint-invariants-selftest

dev-deps:
	$(PYTHON) -m pip install -r requirements-dev.txt

# tier-1 verify (ROADMAP.md)
test:
	PYTHONPATH=src $(PYTHON) -m pytest -x -q

test-fast:
	PYTHONPATH=src $(PYTHON) -m pytest -x -q tests/test_crystal.py \
		tests/test_offload_engine.py tests/test_castore.py \
		tests/test_checkpoint.py tests/test_chunking.py

# invariant lint suite (docs/STATIC_ANALYSIS.md): fails on any finding
# not in the committed baseline; ra-findings.txt is the CI artifact
lint-invariants:
	PYTHONPATH=src $(PYTHON) -m repro.analysis src/repro \
		--baseline analysis-baseline.txt --report ra-findings.txt

# prove the checkers still catch violations: every `# ra-selftest:`
# marker in the fixtures must be reported at exactly its file:line,
# and a raw run over the bad fixtures must exit non-zero
lint-invariants-selftest:
	PYTHONPATH=src $(PYTHON) -m repro.analysis \
		--selftest tests/fixtures/analysis
	@if PYTHONPATH=src $(PYTHON) -m repro.analysis \
		tests/fixtures/analysis --root tests/fixtures/analysis \
		> /dev/null 2>&1; then \
		echo "ERROR: bad fixtures produced a zero exit"; exit 1; \
	else echo "fixture violations exit non-zero: ok"; fi

# durability: WAL framing fuzz + crash/restart fault-injection matrix
test-crash:
	PYTHONPATH=src $(PYTHON) -m pytest -x -q tests/test_wal.py \
		tests/test_crash_recovery.py

bench:
	PYTHONPATH=src:. $(PYTHON) benchmarks/run.py

# tiny-size perf smoke (CI): exercises the engine/pipeline benchmark
# paths, leaves the CSV in bench-smoke.csv and the machine-readable
# summary (rows + engine/gateway counters) in BENCH_smoke.json for the
# artifact uploads
# (redirect, don't pipe: a module failure must fail the make target)
bench-smoke:
	BENCH_SMOKE=1 BENCH_JSON=BENCH_smoke.json PYTHONPATH=src:. \
		$(PYTHON) benchmarks/run.py \
		fig4 fig11 read scrub recovery gateway mesh > bench-smoke.csv
	@cat bench-smoke.csv
	@grep -q '^gateway/latency_p99' bench-smoke.csv
	@grep -q '^recovery/fsync_p95' bench-smoke.csv
	@grep -q '^health/status' bench-smoke.csv
	@grep -q '^health/sampler' bench-smoke.csv
	@grep -q '^health/scrape' bench-smoke.csv
	@test -s obs-health.json
	@$(PYTHON) -c "import json; s = json.load(open('BENCH_smoke.json')); \
		assert s.get('obs'), 'missing obs block in BENCH_smoke.json'"

# perf-regression gate: fresh smoke JSON vs the committed baseline
# (generous cross-machine tolerance bands; ok-flag counters exact —
# see benchmarks/compare.py for the row policy and env overrides)
bench-compare:
	PYTHONPATH=src:. $(PYTHON) benchmarks/compare.py \
		BENCH_baseline.json BENCH_smoke.json

# engine-mesh ablation alone (1 vs 4 forced host devices, static vs
# adaptive fusion); asserts the mesh rows actually landed in the CSV
bench-mesh-smoke:
	BENCH_SMOKE=1 BENCH_JSON=BENCH_mesh.json PYTHONPATH=src:. \
		$(PYTHON) benchmarks/run.py mesh > bench-mesh.csv
	@cat bench-mesh.csv
	@grep -q '^mesh/whale_1dev,' bench-mesh.csv
	@grep -q '^mesh/whale_4dev_sharded,' bench-mesh.csv
	@grep -q '^mesh/fusion_static,' bench-mesh.csv
	@grep -q '^mesh/fusion_adaptive,' bench-mesh.csv
	@grep -q '^mesh/device_' bench-mesh.csv
	@grep -q '^mesh/digest_ok,0.0,ok=1' bench-mesh.csv
