PYTHON ?= python

.PHONY: test test-fast dev-deps bench

dev-deps:
	$(PYTHON) -m pip install -r requirements-dev.txt

# tier-1 verify (ROADMAP.md)
test:
	PYTHONPATH=src $(PYTHON) -m pytest -x -q

test-fast:
	PYTHONPATH=src $(PYTHON) -m pytest -x -q tests/test_crystal.py \
		tests/test_offload_engine.py tests/test_castore.py \
		tests/test_checkpoint.py tests/test_chunking.py

bench:
	PYTHONPATH=src:. $(PYTHON) benchmarks/run.py
