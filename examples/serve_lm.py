"""Batched serving example: prefill a batch of prompts and decode.

  PYTHONPATH=src python examples/serve_lm.py [--arch mixtral-8x7b]
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    if len(sys.argv) == 1:
        sys.argv += ["--arch", "llama3-8b", "--preset", "100m",
                     "--batch", "4", "--prompt-len", "64",
                     "--new-tokens", "16"]
    main()
