"""End-to-end training driver example: ~65M-param llama3-family model,
200 steps with content-addressable checkpointing (the paper's technique
as the framework's checkpoint layer) and one injected failure+restart.

  PYTHONPATH=src python examples/train_lm.py
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "llama3-8b", "--preset", "100m",
                "--steps", "200", "--batch", "2", "--seq", "128",
                "--ckpt-every", "50", "--fail-at", "120"]
    main()
