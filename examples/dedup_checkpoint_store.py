"""The paper's checkpoint workload (Figure 11) end-to-end: successive
checkpoint images written through the CA store, fixed-size vs
content-based chunking, with similarity detection and storage savings.

  PYTHONPATH=src python examples/dedup_checkpoint_store.py
"""
import numpy as np

from repro.core import SAI, SAIConfig, make_store


def checkpoint_series(n_images, image_bytes, change_frac=0.15, seed=0):
    """Synthetic BLCR-like checkpoint images: each successive image
    rewrites a contiguous region in place AND applies an insert/delete
    pair (heap growth shifts content — what makes fixed-block dedup fail
    in the paper: 21-23% fixed vs 76-90% CDC similarity)."""
    rng = np.random.default_rng(seed)
    img = rng.integers(0, 256, image_bytes, dtype=np.uint8)
    out = [img.tobytes()]
    for i in range(1, n_images):
        buf = bytearray(img.tobytes())
        span = int(image_bytes * change_frac)
        start = int(rng.integers(0, len(buf) - span))
        buf[start:start + span] = rng.integers(
            0, 256, span, dtype=np.uint8).tobytes()
        # insert/delete pair: shifts everything between the two points
        k = int(rng.integers(1, 4096))
        ins = int(rng.integers(0, len(buf)))
        buf[ins:ins] = rng.integers(0, 256, k, dtype=np.uint8).tobytes()
        del_at = int(rng.integers(0, len(buf) - k))
        del buf[del_at:del_at + k]
        img = np.frombuffer(bytes(buf), dtype=np.uint8)
        out.append(bytes(buf))
    return out


images = checkpoint_series(n_images=5, image_bytes=2 << 20,
                           change_frac=0.15)
total = sum(len(i) for i in images)

for ca in ("fixed", "cdc-gear"):
    mgr, _ = make_store(4, replication=1)
    # chunk:image ratio scaled to the paper's (256KB-4MB on 264MB images)
    sai = SAI(mgr, SAIConfig(ca=ca, block_size=16 << 10,
                             avg_chunk=16 << 10, min_chunk=4 << 10,
                             max_chunk=64 << 10, hasher="tpu"))
    sims = []
    for i, img in enumerate(images):
        st = sai.write("/ckpt", img)
        if i:
            sims.append(st.similarity)
    stored = mgr.stats()["stored_bytes"]
    print(f"{ca:9s}: wrote {total/1e6:.0f} MB, stored {stored/1e6:.1f} MB "
          f"({100*(1-stored/total):.0f}% saved), "
          f"mean similarity {100*np.mean(sims):.0f}% "
          f"(paper: fixed 21-23%, CDC 76-90%)")
    # every version still restorable
    for v in range(len(images)):
        assert sai.read("/ckpt", version=v) == images[v]
print("all versions verified restorable")
