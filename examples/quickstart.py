"""Quickstart: the paper's technique in one page.

Accelerator-offloaded hashing (HashTPU kernels via the CrystalTPU
runtime) feeding a content-addressable store: write two versions of a
file, watch CDC dedup the unchanged bytes, survive a node failure, and
catch a corruption.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import CrystalTPU, SAI, SAIConfig, make_store
from repro.kernels import ops

rng = np.random.default_rng(0)

# 1) hashing primitives (Pallas kernels, interpret mode on CPU)
data = rng.integers(0, 256, 64 << 10, dtype=np.uint8).tobytes()
digests, final = ops.hash_blocks(data, block_bytes=4096)
print(f"direct hashing: {len(digests)} block digests, "
      f"file digest {final.hex()[:16]}…")

window_hashes = ops.sliding_window_hash(data[:8192], window=48, stride=4)
print(f"sliding-window MD5: {len(window_hashes)} window hashes")

gear = ops.gear_hash(data[:8192])
print(f"gear rolling hash: {len(gear)} positions "
      f"(beyond-paper CDC primitive)")

# 2) the integrated system: CrystalTPU + content-addressable store
manager, nodes = make_store(n_nodes=4, replication=2)
crystal = CrystalTPU()                       # queues + manager threads
sai = SAI(manager, SAIConfig(ca="cdc-gear", avg_chunk=8 << 10,
                             min_chunk=2 << 10, max_chunk=32 << 10,
                             hasher="tpu"), crystal)

v1 = rng.integers(0, 256, 256 << 10, dtype=np.uint8).tobytes()
st = sai.write("/demo/file", v1)
print(f"v1 write: {st.new_blocks} new blocks, {st.new_bytes/1e3:.0f} KB")

v2 = v1[:100_000] + b"a small edit" + v1[100_000:]
st = sai.write("/demo/file", v2)
print(f"v2 write after a 12-byte insert: similarity "
      f"{100*st.similarity:.0f}% — only {st.new_bytes/1e3:.1f} KB stored")

# 3) fault tolerance + integrity
manager.handle_node_failure(0)
assert sai.read("/demo/file") == v2          # replicas serve the read
assert sai.read("/demo/file", version=0) == v1
print("read-after-node-failure OK; both versions intact")

digest = next(iter(manager.block_registry))
for nid in manager.block_registry[digest]:
    if not manager.nodes[nid].failed:
        blk = manager.nodes[nid].blocks[digest]
        manager.nodes[nid].blocks[digest] = bytes([blk[0] ^ 1]) + blk[1:]
try:
    sai.read("/demo/file") and sai.read("/demo/file", version=0)
    print("corruption NOT detected (bug!)")
except IOError as e:
    print(f"corruption detected by content-hash verify: {e}")

crystal.shutdown()
